"""Closed-form solver tests (paper Eq. 23–40): KKT water-filling
properties, constraint satisfaction, joint (b, p) search, offline store."""
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core.solver import (OfflineStore, SegmentItems, build_offline_store,
                               plan_all_partitions, plan_for_partition,
                               solve_joint, waterfill_bits,
                               waterfill_bits_batch)

LN4 = np.log(4.0)

pytestmark = pytest.mark.smoke


def _items(n, seed=0):
    rng = np.random.default_rng(seed)
    return SegmentItems(
        z=rng.uniform(1e3, 1e6, n),
        s=rng.uniform(1e-2, 1e2, n),
        rho=rng.uniform(1e-3, 1e1, n),
    )


class TestWaterfill:
    def test_constraint_satisfied(self):
        it = _items(6)
        for delta in (1e-3, 1e-1, 10.0):
            sol = waterfill_bits(it, delta)
            assert sol.psi_total <= delta * (1 + 1e-9) or \
                np.all(sol.bits == 16.0)   # infeasible -> clamped at b_max

    def test_equal_marginal_condition(self):
        """Eq. 39: z_i rho_i / (s_i e^{-ln4 b_i}) equal across free items."""
        it = _items(8, seed=2)
        sol = waterfill_bits(it, delta=0.05)
        free = (sol.bits > 2.0 + 1e-9) & (sol.bits < 16.0 - 1e-9)
        if free.sum() >= 2:
            marg = it.z[free] * it.rho[free] / (
                it.s[free] * np.exp(-LN4 * sol.bits[free]))
            assert np.allclose(marg, marg[0], rtol=1e-6)

    def test_tighter_budget_means_more_bits(self):
        it = _items(5, seed=3)
        loose = waterfill_bits(it, delta=1.0)
        tight = waterfill_bits(it, delta=1e-3)
        assert np.all(tight.bits >= loose.bits - 1e-9)
        assert tight.payload_bits >= loose.payload_bits

    def test_noisier_layer_gets_more_bits(self):
        """Two identical items except s: the higher-noise-scale item must
        receive at least as many bits (it hurts accuracy more per bit)."""
        it = SegmentItems(z=np.array([1e4, 1e4]),
                          s=np.array([1.0, 100.0]),
                          rho=np.array([1.0, 1.0]))
        sol = waterfill_bits(it, delta=0.01)
        assert sol.bits[1] > sol.bits[0]

    def test_bigger_payload_item_gets_fewer_bits(self):
        it = SegmentItems(z=np.array([1e3, 1e6]),
                          s=np.array([1.0, 1.0]),
                          rho=np.array([1.0, 1.0]))
        sol = waterfill_bits(it, delta=0.01)
        assert sol.bits[1] < sol.bits[0]


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 12), seed=st.integers(0, 9999),
       delta=st.floats(1e-4, 10.0))
def test_property_waterfill_feasible_and_clamped(n, seed, delta):
    it = _items(n, seed=seed)
    sol = waterfill_bits(it, delta)
    assert np.all(sol.bits >= 2.0 - 1e-9)
    assert np.all(sol.bits <= 16.0 + 1e-9)
    # achieved noise never exceeds the budget unless fully clamped at b_max
    if not np.allclose(sol.bits, 16.0):
        assert sol.psi_total <= delta * (1 + 1e-6)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 9999))
def test_property_joint_solution_beats_endpoints(seed):
    """The joint optimum is no worse than always-local or always-server."""
    rng = np.random.default_rng(seed)
    L = 6
    z_w = rng.uniform(1e3, 1e5, L)
    z_x = rng.uniform(1e2, 1e4, L)
    s = rng.uniform(1e-2, 1e1, L)
    rho = rng.uniform(1e-2, 1e1, L)
    o = rng.uniform(1e5, 1e7, L)
    best, plans = solve_joint(z_w, z_x, s, s, rho, o,
                              xi=1e-8, delta_cost=1e-9, eps=1e-8,
                              psi_budget=0.01, input_z=784.0)
    objs = [p.objective for p in plans]
    assert best.objective == min(objs)
    assert len(plans) == L + 1           # p = 0..L


class TestOfflineStore:
    def _store(self):
        L = 4
        rng = np.random.default_rng(0)
        z_w = rng.uniform(1e3, 1e5, L)
        z_x = rng.uniform(1e2, 1e4, L)
        s = rng.uniform(1e-2, 1e1, L)
        rho = rng.uniform(1e-2, 1e1, L)
        o = rng.uniform(1e5, 1e7, L)
        levels = (0.001, 0.005, 0.01, 0.02, 0.05)
        budgets = {a: a * 10 for a in levels}
        return build_offline_store(levels, budgets, z_w, z_x, s, s, rho, o,
                                   xi=1e-8, delta_cost=1e-9, eps=1e-8,
                                   input_z=784.0), levels

    def test_store_covers_all_levels_and_partitions(self):
        store, levels = self._store()
        assert len(store.plans) == len(levels) * 5      # p = 0..4

    def test_lookup_respects_accuracy_budget(self):
        """Alg. 2 step 1: chosen level never exceeds the request's a."""
        store, levels = self._store()
        obj = lambda plan: plan.objective
        for a in (0.0012, 0.006, 0.03, 0.2):
            plan = store.lookup(a, obj)
            lv = [k[0] for k, v in store.plans.items() if v is plan][0]
            assert lv <= a or lv == min(levels)

    def test_lookup_minimizes_runtime_objective(self):
        store, levels = self._store()
        # a runtime objective preferring maximal offload (p small)
        obj = lambda plan: plan.p
        plan = store.lookup(0.01, obj)
        assert plan.p == 0


class TestVectorizedSolver:
    """The batched water-filling path must be plan-for-plan identical to
    the scalar reference (bits, lambda, objective) — the contract that
    lets build_offline_store run one array program per accuracy level."""

    @staticmethod
    def _instance(L, seed):
        rng = np.random.default_rng(seed)
        return dict(
            layer_z_w=rng.uniform(1e3, 1e6, L),
            layer_z_x=rng.uniform(1e2, 1e4, L),
            layer_s_w=rng.uniform(1e-2, 1e2, L),
            layer_s_x=rng.uniform(1e-2, 1e2, L),
            layer_rho=rng.uniform(1e-3, 1e1, L),
        )

    def test_matches_scalar_plan_for_plan(self):
        coef = dict(xi=1e-8, delta_cost=1e-9, eps=1e-8, input_z=784.0)
        for seed in range(4):
            for L in (1, 5, 17):
                inst = self._instance(L, seed)
                rng = np.random.default_rng(seed + 100)
                o = rng.uniform(1e5, 1e7, L)
                o_cum = np.cumsum(o)
                o_total = float(o_cum[-1])
                # budgets spanning lo-clamp, interior, and infeasible
                for budget in (1e-5, 1e-2, 1.0, 500.0):
                    vec = plan_all_partitions(o_cum=o_cum, o_total=o_total,
                                              psi_budget=budget, **inst,
                                              **coef)
                    assert len(vec) == L + 1
                    for p in range(L + 1):
                        ref = plan_for_partition(p, o_cum=o_cum,
                                                 o_total=o_total,
                                                 psi_budget=budget, **inst,
                                                 **coef)
                        np.testing.assert_allclose(vec[p].bits_w, ref.bits_w,
                                                   rtol=1e-9, atol=1e-9)
                        np.testing.assert_allclose(vec[p].bits_x, ref.bits_x,
                                                   rtol=1e-9)
                        np.testing.assert_allclose(vec[p].objective,
                                                   ref.objective, rtol=1e-9)
                        np.testing.assert_allclose(vec[p].psi_total,
                                                   ref.psi_total, rtol=1e-9)
                        np.testing.assert_allclose(vec[p].payload_bits,
                                                   ref.payload_bits,
                                                   rtol=1e-9)
                        np.testing.assert_allclose(vec[p].payload_x_bits,
                                                   ref.payload_x_bits,
                                                   rtol=1e-9)

    def test_batched_waterfill_matches_scalar_rowwise(self):
        """Directly: each row of the batched solve == waterfill_bits on
        that row's item subset (including the KKT multiplier)."""
        rng = np.random.default_rng(7)
        R, I = 9, 12
        z = rng.uniform(1e3, 1e6, (R, I))
        s = rng.uniform(1e-2, 1e2, (R, I))
        rho = rng.uniform(1e-3, 1e1, (R, I))
        valid = np.zeros((R, I), bool)
        for r in range(R):
            valid[r, :rng.integers(1, I + 1)] = True
        for delta in (1e-4, 0.05, 10.0):
            bits, lam, psi, payload = waterfill_bits_batch(
                z, s, rho, valid, delta)
            for r in range(R):
                m = valid[r]
                sol = waterfill_bits(SegmentItems(z[r, m], s[r, m],
                                                  rho[r, m]), delta)
                np.testing.assert_allclose(bits[r, m], sol.bits,
                                           rtol=1e-9, atol=1e-9)
                np.testing.assert_allclose(lam[r], sol.lam, rtol=1e-9)
                np.testing.assert_allclose(psi[r], sol.psi_total, rtol=1e-9)
                np.testing.assert_allclose(payload[r], sol.payload_bits,
                                           rtol=1e-9)
                assert np.all(bits[r, ~m] == 0.0)

    def test_store_vectorized_equals_reference(self):
        inst = self._instance(6, seed=3)
        rng = np.random.default_rng(3)
        o = rng.uniform(1e5, 1e7, 6)
        levels = (0.001, 0.005, 0.02)
        budgets = {a: a * 10 for a in levels}
        kw = dict(levels=levels, budgets=budgets, layer_o=o, xi=1e-8,
                  delta_cost=1e-9, eps=1e-8, input_z=784.0, **inst)
        vec = build_offline_store(vectorized=True, **kw)
        ref = build_offline_store(vectorized=False, **kw)
        assert vec.plans.keys() == ref.plans.keys()
        for key in ref.plans:
            np.testing.assert_allclose(vec.plans[key].bits_w,
                                       ref.plans[key].bits_w,
                                       rtol=1e-9, atol=1e-9)
            np.testing.assert_allclose(vec.plans[key].objective,
                                       ref.plans[key].objective, rtol=1e-9)

    def test_infeasible_budget_lam_defined(self):
        """Regression: waterfill_bits must not hit an unbound ``lam`` and
        the batched path must agree on the fully-clamped solution."""
        it = SegmentItems(z=np.array([1e4, 1e5]), s=np.array([1e8, 1e9]),
                          rho=np.array([1e-6, 1e-6]))
        sol = waterfill_bits(it, delta=1e-12)
        assert np.all(sol.bits == 16.0) and np.isfinite(sol.psi_total)
        bits, lam, psi, _ = waterfill_bits_batch(
            it.z[None, :], it.s[None, :], it.rho[None, :],
            np.ones((1, 2), bool), 1e-12)
        np.testing.assert_allclose(bits[0], sol.bits)
        np.testing.assert_allclose(lam[0], sol.lam, rtol=1e-9)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 999), delta=st.floats(1e-3, 1.0))
def test_property_waterfill_beats_brute_force_grid(seed, delta):
    """The closed-form KKT solution must (weakly) beat a dense grid search
    over feasible bit vectors — the optimality claim of Eq. 27/39/40."""
    rng = np.random.default_rng(seed)
    n = 2
    it = SegmentItems(z=rng.uniform(1e3, 1e5, n),
                      s=rng.uniform(1e-1, 1e1, n),
                      rho=rng.uniform(1e-2, 1e0, n))
    sol = waterfill_bits(it, delta)
    if np.allclose(sol.bits, 16.0):      # infeasible budget: nothing to check
        return
    grid = np.arange(2.0, 16.01, 0.05)
    best_payload = np.inf
    for b0 in grid:
        # for fixed b0, the cheapest feasible b1 is determined analytically
        rem = delta - it.s[0] / it.rho[0] * np.exp(-np.log(4.0) * b0)
        if rem <= 0:
            continue
        b1 = max(-np.log(rem * it.rho[1] / it.s[1]) / np.log(4.0), 2.0)
        if b1 > 16.0:
            continue
        best_payload = min(best_payload, b0 * it.z[0] + b1 * it.z[1])
    if np.isfinite(best_payload):
        assert sol.payload_bits <= best_payload * (1 + 1e-3)
