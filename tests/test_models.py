"""Per-architecture smoke tests (deliverable f) + model-level invariants:
every assigned arch instantiates its REDUCED variant, runs one forward and
one train step on CPU, asserts output shapes + no NaNs; decode agrees with
teacher-forced forward; padded-head TP layout computes the identical
function; M-RoPE degenerates to RoPE on text."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.models import rope as rope_lib
from repro.models import transformer as T
from repro.models.frontend import mrope_positions, stub_embeddings
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_loop import make_train_step

KEY = jax.random.key(0)


def _f32(cfg):
    return dataclasses.replace(cfg, dtype="float32")


@pytest.fixture(scope="module")
def reduced_params():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = _f32(get_config(name).reduced())
            cache[name] = (cfg, T.init_params(KEY, cfg))
        return cache[name]

    return get


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
class TestArchSmoke:
    def test_forward_shapes_no_nans(self, arch, reduced_params):
        cfg, params = reduced_params(arch)
        b, s = 2, 32
        if cfg.frontend != "none":
            emb = stub_embeddings(KEY, cfg, b, s, jnp.float32)
            pos = mrope_positions(b, s) if cfg.rope == "mrope" else None
            logits, aux = T.forward(params, cfg, embeds=emb, positions=pos)
        else:
            toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
            logits, aux = T.forward(params, cfg, toks)
        assert logits.shape == (b, s, cfg.padded_vocab())
        assert not bool(jnp.isnan(logits).any())

    def test_train_step_runs_and_is_finite(self, arch, reduced_params):
        cfg, params = reduced_params(arch)
        b, s = 2, 32
        step = make_train_step(cfg, AdamWConfig(lr=1e-3), remat=False)
        opt = init_opt_state(params)
        batch = {"labels": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)}
        if cfg.frontend != "none":
            batch["embeds"] = stub_embeddings(KEY, cfg, b, s, jnp.float32)
            if cfg.rope == "mrope":
                batch["positions"] = mrope_positions(b, s)
        else:
            batch["tokens"] = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
        params2, opt2, metrics = step(params, opt, batch)
        assert np.isfinite(float(metrics["loss"]))
        # params actually changed
        d = jax.tree.leaves(jax.tree.map(
            lambda a, b_: float(jnp.max(jnp.abs(a - b_))), params, params2))
        assert max(d) > 0

    def test_decode_matches_teacher_forcing(self, arch, reduced_params):
        cfg, params = reduced_params(arch)
        if cfg.moe is not None:
            # capacity drops make token routing prefix-dependent; use a
            # no-drop capacity so decode and forward route identically
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        b, s = 2, 24
        toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
        if cfg.frontend != "none":
            pytest.skip("frontend archs decode from token ids only after "
                        "prefill over embeds; covered by prefill test")
        lg_pre, caches, _ = T.prefill(params, cfg, toks, max_len=64,
                                      cache_dtype=jnp.float32)
        nxt = jnp.argmax(lg_pre[:, -1:], -1).astype(jnp.int32)
        lg_dec, _ = T.decode_step(params, cfg, nxt, caches,
                                  jnp.array(s, jnp.int32))
        lg_full, _ = T.forward(params, cfg, jnp.concatenate([toks, nxt], 1))
        np.testing.assert_allclose(np.asarray(lg_dec[:, 0]),
                                   np.asarray(lg_full[:, -1]),
                                   atol=2e-4, rtol=1e-3)

    def test_prefill_logits_match_forward(self, arch, reduced_params):
        cfg, params = reduced_params(arch)
        b, s = 2, 32
        if cfg.frontend != "none":
            emb = stub_embeddings(KEY, cfg, b, s, jnp.float32)
            pos = mrope_positions(b, s) if cfg.rope == "mrope" else None
            lg_f, _ = T.forward(params, cfg, embeds=emb, positions=pos)
            lg_p, _, _ = T.prefill(params, cfg, embeds=emb, positions=pos,
                                   max_len=64)
        else:
            toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
            lg_f, _ = T.forward(params, cfg, toks)
            lg_p, _, _ = T.prefill(params, cfg, toks, max_len=64)
        np.testing.assert_allclose(np.asarray(lg_p), np.asarray(lg_f),
                                   atol=2e-4, rtol=1e-3)


class TestPaddedHeadExactness:
    """tp_pad changes tensor layouts but must NOT change the function."""

    @pytest.mark.parametrize("arch", ["smollm-135m", "qwen3-14b",
                                      "qwen1.5-4b"])
    def test_padded_equals_unpadded(self, arch):
        base = _f32(get_config(arch).reduced())
        # reduced() turns padding off; re-enable it for the padded twin
        padded = dataclasses.replace(base, tp_pad=16)
        kv, g = base.padded_heads()
        kvp, gp = padded.padded_heads()
        assert (kvp, gp) != (kv, g), "test needs real padding"
        p_base = T.init_params(KEY, base)
        p_pad = T.init_params(KEY, padded)
        # copy the real heads of the base init into the padded layout
        for per in range(len(p_base["blocks"])):
            bb, bp = p_base["blocks"][per], p_pad["blocks"][per]
            if "attn" not in bb:
                continue
            hd = base.resolved_head_dim()
            wq = bb["attn"]["wq"].reshape(-1, base.d_model, kv, g, hd)
            wqp = jnp.zeros_like(
                bp["attn"]["wq"]).reshape(-1, base.d_model, kvp, gp, hd)
            wqp = wqp.at[:, :, :kv, :g].set(wq)
            bp["attn"]["wq"] = wqp.reshape(bp["attn"]["wq"].shape)
            wo = bb["attn"]["wo"].reshape(-1, kv, g, hd, base.d_model)
            wop = jnp.zeros_like(
                bp["attn"]["wo"]).reshape(-1, kvp, gp, hd, base.d_model)
            # padded wo rows non-zero on purpose: the mask must kill them
            wop = wop + 7.7
            wop = wop.at[:, :kv, :g].set(wo)
            bp["attn"]["wo"] = wop.reshape(bp["attn"]["wo"].shape)
            kpad = jnp.zeros_like(bp["attn"]["wk"])
            bp["attn"]["wk"] = kpad.at[:, :, :kv].set(bb["attn"]["wk"])
            bp["attn"]["wv"] = kpad.at[:, :, :kv].set(bb["attn"]["wv"])
            if "bq" in bb["attn"]:
                bq = bb["attn"]["bq"].reshape(-1, kv, g, hd)
                bqp = jnp.zeros_like(bp["attn"]["bq"]).reshape(-1, kvp, gp, hd)
                bp["attn"]["bq"] = bqp.at[:, :kv, :g].set(bq).reshape(
                    bp["attn"]["bq"].shape)
                bkp = jnp.zeros_like(bp["attn"]["bk"])
                bp["attn"]["bk"] = bkp.at[:, :kv].set(bb["attn"]["bk"])
                bp["attn"]["bv"] = bkp.at[:, :kv].set(bb["attn"]["bv"])
        toks = jax.random.randint(KEY, (2, 16), 0, base.vocab_size)
        lg_b, _ = T.forward(p_base, base, toks)
        lg_p, _ = T.forward(p_pad, padded, toks)
        np.testing.assert_allclose(np.asarray(lg_b), np.asarray(lg_p),
                                   atol=2e-4, rtol=1e-3)


class TestRope:
    def test_mrope_degenerates_to_rope_on_text(self):
        x = jax.random.normal(KEY, (2, 8, 4, 64))
        pos = rope_lib.text_positions(2, 8)
        r1 = rope_lib.apply_rope("rope", x, pos, 10000.0)
        pos3 = jnp.broadcast_to(pos[None], (3, 2, 8))
        r2 = rope_lib.apply_rope("mrope", x, pos3, 10000.0)
        # mrope section frequencies are a permutation of rope's when all
        # three streams carry identical positions -> same rotation set;
        # Qwen2-VL's property is angle-set equality, we check value-level
        # closeness of the norms (rotation preserves them) and exactness
        # of the t-section slots
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(r1), axis=-1),
            np.linalg.norm(np.asarray(r2), axis=-1), rtol=1e-5)

    def test_rope2d_rotates_only_first_half(self):
        x = jax.random.normal(KEY, (1, 4, 2, 64))
        pos = rope_lib.text_positions(1, 4)
        out = rope_lib.apply_rope("rope2d", x, pos, 10000.0)
        np.testing.assert_allclose(np.asarray(out[..., 32:]),
                                   np.asarray(x[..., 32:]), atol=1e-6)

    def test_rope_preserves_norm(self):
        x = jax.random.normal(KEY, (2, 8, 3, 32))
        pos = rope_lib.text_positions(2, 8)
        out = rope_lib.apply_rope("rope", x, pos, 10000.0)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(out), axis=-1),
            np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)

    def test_rope_relative_property(self):
        """<rope(q,i), rope(k,j)> depends only on i-j."""
        q = jax.random.normal(KEY, (1, 1, 1, 32))
        k = jax.random.normal(jax.random.key(1), (1, 1, 1, 32))

        def score(i, j):
            qi = rope_lib.apply_rope("rope", q, jnp.array([[i]]), 10000.0)
            kj = rope_lib.apply_rope("rope", k, jnp.array([[j]]), 10000.0)
            return float(jnp.sum(qi * kj))

        assert abs(score(5, 3) - score(9, 7)) < 1e-4


class TestSlidingWindow:
    def test_window_limits_context(self):
        """A token further than `window` back must not influence logits."""
        cfg = _f32(dataclasses.replace(
            get_config("smollm-135m").reduced(), sliding_window=8))
        params = T.init_params(KEY, cfg)
        s = 32
        toks = jax.random.randint(KEY, (1, s), 0, cfg.vocab_size)
        toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)
        lg1, _ = T.forward(params, cfg, toks)
        lg2, _ = T.forward(params, cfg, toks2)
        # last position is > window away from position 0
        np.testing.assert_allclose(np.asarray(lg1[0, -1]),
                                   np.asarray(lg2[0, -1]), atol=1e-5)
        # but position 1 (inside the window of pos 0) does change
        assert float(jnp.max(jnp.abs(lg1[0, 1] - lg2[0, 1]))) > 1e-6

    def test_ring_buffer_wraps_correctly(self):
        """Decode past the window: ring-buffer attention == windowed
        forward on the full sequence."""
        cfg = _f32(dataclasses.replace(
            get_config("smollm-135m").reduced(), sliding_window=8))
        params = T.init_params(KEY, cfg)
        s, extra = 16, 6
        toks = jax.random.randint(KEY, (1, s + extra), 0, cfg.vocab_size)
        _, caches, _ = T.prefill(params, cfg, toks[:, :s], max_len=s + extra,
                                 cache_dtype=jnp.float32)
        for i in range(extra):
            lg_dec, caches = T.decode_step(
                params, cfg, toks[:, s + i:s + i + 1], caches,
                jnp.array(s + i, jnp.int32))
        lg_full, _ = T.forward(params, cfg, toks)
        # compare the logits of the LAST decoded token
        np.testing.assert_allclose(np.asarray(lg_dec[:, 0]),
                                   np.asarray(lg_full[:, -1]),
                                   atol=2e-4, rtol=1e-3)


class TestQuantizedServing:
    """int8 serving weights (QPART quantization over the whole stack):
    the wire structs must dequantize to a near-identical model, and codes
    must be unsigned (8-bit codes wrap in int8 — regression test)."""

    def test_int8_forward_close(self):
        from repro.core.quantizer import quantize_params_for_serving
        cfg = _f32(get_config("qwen3-14b").reduced())
        params = T.init_params(KEY, cfg)
        qparams = quantize_params_for_serving(params, 8)
        toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
        lg, _ = T.forward(params, cfg, toks)
        lgq, _ = T.forward(qparams, cfg, toks)
        cos = float(jnp.sum(lg * lgq) /
                    (jnp.linalg.norm(lg) * jnp.linalg.norm(lgq)))
        assert cos > 0.995

    def test_codes_unsigned(self):
        from repro.core.quantizer import quantize_stacked
        w = jax.random.normal(KEY, (2, 8, 8))
        q = quantize_stacked(w, 8)
        assert q["codes"].dtype == jnp.uint8
        wd = q["codes"].astype(jnp.float32) * q["scale"] + q["mu"]
        err = float(jnp.max(jnp.abs(w - wd)) / jnp.max(jnp.abs(w)))
        assert err < 0.02

    def test_int8_decode_runs(self):
        from repro.core.quantizer import quantize_params_for_serving
        cfg = _f32(get_config("smollm-135m").reduced())
        params = quantize_params_for_serving(T.init_params(KEY, cfg), 8)
        caches = T.init_cache(cfg, 2, 32, jnp.float32)
        tok = jnp.zeros((2, 1), jnp.int32)
        lg, _ = T.decode_step(params, cfg, tok, caches, jnp.array(0))
        assert not bool(jnp.isnan(lg).any())


class TestAttentionImplParity:
    def test_flash_impl_matches_blocked_through_model(self, monkeypatch):
        """REPRO_ATTN_IMPL=flash (Pallas, interpret on CPU) must compute
        the exact same logits as the pure-JAX blocked attention."""
        cfg = _f32(get_config("smollm-135m").reduced())
        params = T.init_params(KEY, cfg)
        toks = jax.random.randint(KEY, (2, 64), 0, cfg.vocab_size)
        monkeypatch.setenv("REPRO_ATTN_IMPL", "blocked")
        lg1, _ = T.forward(params, cfg, toks)
        monkeypatch.setenv("REPRO_ATTN_IMPL", "flash")
        lg2, _ = T.forward(params, cfg, toks)
        np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2),
                                   atol=1e-5)

    def test_int4_packed_forward_close(self):
        from repro.core.quantizer import quantize_params_for_serving
        cfg = _f32(get_config("qwen3-14b").reduced())
        params = T.init_params(KEY, cfg)
        qparams = quantize_params_for_serving(params, 4)
        # packing really halves the code bytes
        wq = qparams["blocks"][0]["attn"]["wq"]
        assert "codes_packed" in wq
        assert wq["codes_packed"].shape[-1] == \
            params["blocks"][0]["attn"]["wq"].shape[-1] // 2
        toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
        lg, _ = T.forward(params, cfg, toks)
        lgq, _ = T.forward(qparams, cfg, toks)
        cos = float(jnp.sum(lg * lgq) /
                    (jnp.linalg.norm(lg) * jnp.linalg.norm(lgq)))
        assert cos > 0.9        # int4 is lossy; cosine stays high
