"""Tests for the model-agnostic serving API: the ``ModelBackend``
protocol, a decoder transformer through the full QPART pipeline,
multi-context stores, plan-time device-memory enforcement, and the
``ServingError`` hierarchy."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.configs.classifier import MNIST_MLP
from repro.core.cost_model import Channel, DeviceProfile, ObjectiveWeights
from repro.core.partition import plan_memory_bytes, segment_memory_bytes
from repro.models import transformer as T
from repro.models.classifier import init_classifier
from repro.serving.backends import ClassifierBackend, TransformerBackend
from repro.serving.deployment import Deployment, ReferenceContext
from repro.serving.errors import (NotCalibratedError, ServingError,
                                  StoreMissingError, UnknownModelError)
from repro.serving.qpart_server import QPARTServer
from repro.serving.simulator import InferenceRequest

SEQ = 16


def tiny_lm_config():
    return dataclasses.replace(
        get_config("smollm-135m").reduced(), name="smollm-tiny",
        d_model=64, num_heads=2, num_kv_heads=1, head_dim=32, d_ff=128,
        vocab_size=32, tp_pad=1, dtype="float32")


def cycle_batch(rng, cfg, n):
    """Deterministic next-token task: t[i+1] = (t[i] + 1) mod V. x is the
    (B, SEQ) prompt, y the next token after the last position."""
    start = rng.integers(0, cfg.vocab_size, size=(n, 1))
    toks = (start + np.arange(SEQ + 1)[None, :]) % cfg.vocab_size
    return (jnp.asarray(toks[:, :SEQ], jnp.int32),
            jnp.asarray(toks[:, SEQ], jnp.int32))


@pytest.fixture(scope="module")
def trained_lm():
    cfg = tiny_lm_config()
    params = T.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)

    def loss_fn(p, toks):
        logits, _ = T.forward(p, cfg, toks[:, :-1])
        lp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(lp, toks[:, 1:][..., None], -1))

    @jax.jit
    def step(p, toks):
        _, g = jax.value_and_grad(loss_fn)(p, toks)
        return jax.tree.map(lambda a, b: a - 0.5 * b, p, g)

    for _ in range(300):
        start = rng.integers(0, cfg.vocab_size, size=(32, 1))
        toks = jnp.asarray((start + np.arange(SEQ + 1)[None, :])
                           % cfg.vocab_size, jnp.int32)
        params = step(params, toks)
    return cfg, params, rng


@pytest.fixture(scope="module")
def lm_served(trained_lm):
    cfg, params, rng = trained_lm
    backend = TransformerBackend(cfg, params, seq_len=SEQ)
    x_cal, y_cal = cycle_batch(rng, cfg, 128)
    srv = QPARTServer()
    srv.register("smollm", backend, x_cal, y_cal)
    srv.calibrate("smollm")
    dev, ch, w = DeviceProfile(), Channel(capacity_bps=2e6), ObjectiveWeights()
    srv.build_store("smollm", dev, ch, w)
    return srv, backend, (dev, ch, w)


class TestTransformerBackend:
    def test_forward_matches_scan_forward(self, trained_lm):
        """The backend's block-by-block forward is the same math as the
        production lax.scan forward."""
        cfg, params, rng = trained_lm
        backend = TransformerBackend(cfg, params, seq_len=SEQ)
        x, _ = cycle_batch(rng, cfg, 8)
        ref, _ = T.forward(params, cfg, x)
        np.testing.assert_allclose(np.asarray(backend.forward(x)),
                                   np.asarray(ref[:, -1, :]),
                                   rtol=1e-4, atol=1e-5)

    def test_layer_specs_drop_embed_row(self, trained_lm):
        cfg, params, _ = trained_lm
        backend = TransformerBackend(cfg, params, seq_len=SEQ)
        specs = backend.layer_specs()
        assert len(specs) == cfg.num_layers == backend.num_layers
        assert all(sp.o > 0 for sp in specs)

    def test_e2e_calibrate_build_serve_execute(self, lm_served, trained_lm):
        """A decoder transformer runs the FULL pipeline: calibrate →
        build_store → serve → Deployment.execute, with measured accuracy
        degradation reported."""
        cfg, params, rng = trained_lm
        srv, backend, (dev, ch, w) = lm_served
        m = srv.models["smollm"]
        assert m.base_accuracy > 0.9          # the cycle task is learnable
        assert np.all(m.s_w > 0) and np.all(m.rho > 0)
        x_te, y_te = cycle_batch(rng, cfg, 96)
        dep = srv.serve(InferenceRequest("smollm", 0.01, dev, ch, w,
                                         segment_cached=True))
        assert isinstance(dep, Deployment)
        res = dep.execute(x_te, y_te)
        assert res.accuracy is not None
        assert res.accuracy_degradation is not None
        assert res.objective > 0

    def test_quantized_partitioned_execution(self, lm_served, trained_lm):
        """Force the all-blocks-on-device plan: quantized blocks + a
        quantized cut activation + fp server tail really execute, and the
        quantized payload beats f32."""
        cfg, params, rng = trained_lm
        srv, backend, _ = lm_served
        m = srv.models["smollm"]
        L = cfg.num_layers
        plan = m.store().plans[(0.02, L)]
        specs = backend.layer_specs()
        assert plan.payload_bits < sum(sp.z_w for sp in specs) * 32.0
        x_te, y_te = cycle_batch(rng, cfg, 96)
        acc = srv.execute_partitioned("smollm", plan, x_te, y_te)
        assert 0.0 <= acc <= 1.0
        # the quantized model retains most of the (perfect) base accuracy
        assert acc > 0.5

    def test_segment_memory_matches_plan(self, lm_served):
        srv, backend, _ = lm_served
        m = srv.models["smollm"]
        plan = m.store().plans[(0.01, backend.num_layers)]
        seg = backend.split(plan)
        # analytic plan-time footprint vs the materialized segment: the
        # plan uses the cost-model z_w (analytic block params), the
        # segment counts real leaves — they agree within the small
        # analytic/actual param-count gap (A_log/D scalars etc.)
        assert segment_memory_bytes(seg) == pytest.approx(
            plan.device_memory_bytes, rel=0.05)
        assert plan_memory_bytes(plan, backend.layer_specs()) \
            == pytest.approx(plan.device_memory_bytes, rel=1e-9)


class TestMultiContextStores:
    def test_stores_accumulate_per_context(self, lm_served):
        srv, backend, (dev, ch, w) = lm_served
        m = srv.models["smollm"]
        n_before = len(m.stores)
        ch2 = Channel(capacity_bps=100e6)
        ctx2 = srv.build_store("smollm", dev, ch2, w)
        assert len(m.stores) == n_before + 1
        assert m.store(ctx2) is m.stores[ctx2]
        # the first context's store is still addressable
        ctx1 = ReferenceContext(dev, ch, w)
        assert m.store(ctx1) is not m.store(ctx2)
        # default follows the most recent build (old overwrite semantics)
        assert m.default_context == ctx2
        # serving against an explicit context picks that store's plans
        req = InferenceRequest("smollm", 0.01, dev, ch, w)
        dep1 = srv.serve(req, context=ctx1)
        assert any(dep1.plan is pl for pl in m.store(ctx1).plans.values())
        # restore default for other tests
        srv.build_store("smollm", dev, ch, w)

    def test_missing_context_raises(self, lm_served):
        srv, backend, (dev, ch, w) = lm_served
        ghost = ReferenceContext(dev, Channel(capacity_bps=123.0), w)
        with pytest.raises(StoreMissingError):
            srv.serve(InferenceRequest("smollm", 0.01, dev, ch, w),
                      context=ghost)


class TestMemoryEnforcement:
    @pytest.fixture(scope="class")
    def served(self):
        """Pricing-only classifier server (fabricated calibration)."""
        srv = QPARTServer()
        x = np.zeros((4, 28, 28), np.float32)
        y = np.zeros(4, np.int32)
        srv.register("mnist", ClassifierBackend(MNIST_MLP, None), x, y)
        m = srv.models["mnist"]
        L = MNIST_MLP.num_layers
        m.s_w = np.ones(L)
        m.s_x = np.ones(L)
        m.rho = np.full(L, 0.1)
        m.delta_table = {a: a * 50 for a in srv.levels}
        dev = DeviceProfile()
        ch = Channel(capacity_bps=2e6)
        w = ObjectiveWeights()
        srv.build_store("mnist", dev, ch, w)
        return srv, dev, ch, w

    def test_infeasible_candidates_rejected(self, served):
        srv, dev, ch, w = served
        m = srv.models["mnist"]
        store = m.store()
        # unconstrained choice keeps layers on-device (congested uplink)
        req = InferenceRequest("mnist", 0.01, dev, ch, w,
                               segment_cached=True)
        p_free = srv.serve(req).plan.p
        assert p_free > 0
        # a device too small for ANY quantized segment: only p=0 fits
        tiny = dataclasses.replace(dev, memory_bytes=10.0)
        dep = srv.serve(InferenceRequest("mnist", 0.01, tiny, ch, w,
                                         segment_cached=True))
        assert dep.plan.p == 0
        # a mid-size budget: the chosen segment must fit it
        lv = store.level_for(0.01)
        mems = store.level_memory_rows(lv)
        cap = float(np.sort(mems[mems > 0])[0]) * 1.5
        mid = dataclasses.replace(dev, memory_bytes=cap)
        dep2 = srv.serve(InferenceRequest("mnist", 0.01, mid, ch, w,
                                          segment_cached=True))
        assert 0 < dep2.plan.device_memory_bytes <= cap or dep2.plan.p == 0

    def test_batch_matches_scalar_under_memory_pressure(self, served):
        srv, dev, ch, w = served
        tiny = dataclasses.replace(dev, memory_bytes=10.0)
        mid = dataclasses.replace(dev, memory_bytes=300e3)
        reqs = [InferenceRequest("mnist", 0.01,
                                 (dev, tiny, mid)[i % 3], ch, w,
                                 segment_cached=True) for i in range(9)]
        batch = srv.serve_batch(reqs)
        for req, br in zip(reqs, batch):
            sr = srv.serve(req)
            assert br.plan is sr.plan
            assert br.objective == pytest.approx(sr.objective, rel=1e-12)
            assert br.plan.device_memory_bytes <= req.device.memory_bytes

    def test_scheduler_respects_memory(self, served):
        from repro.serving.scheduler import WorkloadBalancer
        from repro.core.cost_model import ServerProfile
        srv, dev, ch, w = served
        tiny = dataclasses.replace(dev, memory_bytes=10.0)
        reqs = [InferenceRequest("mnist", 0.01, tiny, ch, w,
                                 segment_cached=True) for _ in range(4)]
        out = WorkloadBalancer(ServerProfile()).schedule(srv, reqs)
        assert all(sr.deployment.plan.p == 0 for sr in out)


class TestServingErrors:
    def test_unknown_model(self):
        srv = QPARTServer()
        req = InferenceRequest("ghost", 0.01, DeviceProfile(), Channel())
        with pytest.raises(UnknownModelError):
            srv.serve(req)
        with pytest.raises(ServingError):       # one catchable root
            srv.serve_batch([req])
        with pytest.raises(UnknownModelError):
            srv.calibrate("ghost")

    def test_uncalibrated_model(self):
        srv = QPARTServer()
        srv.register("mnist", ClassifierBackend(
            MNIST_MLP, init_classifier(jax.random.key(0), MNIST_MLP)),
            np.zeros((4, 28, 28), np.float32), np.zeros(4, np.int32))
        req = InferenceRequest("mnist", 0.01, DeviceProfile(), Channel())
        with pytest.raises(NotCalibratedError):
            srv.serve(req)
        with pytest.raises(NotCalibratedError):
            srv.serve_batch([req])
        with pytest.raises(NotCalibratedError):
            srv.build_store("mnist", DeviceProfile(), Channel(),
                            ObjectiveWeights())

    def test_errors_are_serving_errors(self):
        assert issubclass(UnknownModelError, ServingError)
        assert issubclass(NotCalibratedError, ServingError)
        assert issubclass(StoreMissingError, ServingError)
