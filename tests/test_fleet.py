"""Event-driven fleet engine tests (serving.engine, DESIGN.md §8):
degenerate-case lock against the one-shot scheduler, continuous-time
queue dynamics, engine-managed device segment caches, deadline/SLO
admission (reject + degrade), multi-server fleets, policy-ordering
properties (hypothesis), and fleet metrics sanity."""
import dataclasses

import numpy as np
import pytest

from repro.configs.classifier import CIFAR_CNN, MNIST_MLP
from repro.core.cost_model import (Channel, DeviceProfile, ObjectiveWeights,
                                   ServerProfile)
from repro.serving.engine import FleetEngine
from repro.serving.qpart_server import QPARTServer
from repro.serving.scheduler import WorkloadBalancer, total_latency
from repro.serving.simulator import InferenceRequest
from repro.serving.testing import stub_classifier_server

from tests._hypothesis_shim import given, settings, st

pytestmark = pytest.mark.smoke

DEV = DeviceProfile()
CH = Channel(capacity_bps=2e6)
W = ObjectiveWeights()


def stub_server(configs=(("mnist", MNIST_MLP),), server=None,
                device=DEV, channel=CH, weights=W) -> QPARTServer:
    """Pricing-only QPART server (repro.serving.testing): synthetic
    calibration constants, real offline store — the fleet engine never
    executes models, so no training is needed."""
    return stub_classifier_server(configs, server=server, device=device,
                                  channel=channel, weights=weights)


def req(budget=0.01, device=DEV, channel=CH, weights=W, **kw):
    return InferenceRequest("mnist", budget, device, channel, weights, **kw)


# ---------------------------------------------------------------------------
class TestDegenerateLock:
    """One server + simultaneous arrivals == the one-shot scheduler.

    The genuine behavioral lock is against the INDEPENDENT scalar
    reference (``_serve_under_load``) — here and in test_scheduler.py.
    The first test only pins the schedule() ↔ engine delegation mapping
    (record order and field wiring), since schedule() now runs the
    engine itself."""

    def test_engine_matches_workload_balancer(self):
        srv = stub_server()
        strong = dataclasses.replace(DEV, f_clock=2e9)
        reqs = [req(0.01 if i % 2 else 0.004,
                    device=strong if i % 3 == 0 else DEV,
                    segment_cached=bool(i % 2)) for i in range(10)]
        for policy in ("fcfs", "balanced"):
            sched = WorkloadBalancer(ServerProfile(),
                                     policy=policy).schedule(srv, reqs)
            eng = FleetEngine(srv, servers=[ServerProfile()], policy=policy)
            recs = eng.run(reqs).records
            assert len(recs) == len(sched)
            for rec, sr in zip(recs, sched):
                assert rec.deployment.plan is sr.result.plan
                assert rec.deployment.objective == sr.result.objective
                assert rec.queue_delay == sr.result.extra["queue_delay"]
                assert rec.start_order == sr.start_order

    def test_scalar_reference_pricing(self):
        """Engine admission == per-request Alg. 2 re-pricing, decision
        for decision (the same lock test_scheduler runs, directly on the
        engine API)."""
        srv = stub_server()
        bal = WorkloadBalancer(ServerProfile(), policy="fcfs")
        reqs = [req(segment_cached=True) for _ in range(8)]
        recs = FleetEngine(srv, servers=[ServerProfile()]).run(reqs).records
        queue = 0.0
        for rec in recs:
            ref = bal._serve_under_load(srv, rec.request, queue)
            assert rec.deployment.plan is ref.plan
            assert rec.deployment.objective == pytest.approx(ref.objective,
                                                             rel=1e-9)
            queue += ref.costs.t_server


# ---------------------------------------------------------------------------
class TestContinuousTime:
    def test_spread_arrivals_see_no_queue(self):
        """Arrivals far apart in time drain the backlog between epochs;
        simultaneous arrivals stack up."""
        srv = stub_server()
        burst = [req(segment_cached=True) for _ in range(16)]
        m_burst = FleetEngine(srv).run(burst)
        assert max(r.queue_delay for r in m_burst.records) > 0
        spread = [dataclasses.replace(r, arrival_time=i * 10.0)
                  for i, r in enumerate(burst)]
        m_spread = FleetEngine(srv).run(spread)
        assert max(r.queue_delay for r in m_spread.records) == 0.0
        # identical requests at zero load: every epoch picks the same plan
        ps = {r.deployment.plan.p for r in m_spread.records}
        assert len(ps) == 1

    def test_timeline_stage_order(self):
        srv = stub_server()
        recs = FleetEngine(srv).run([req() for _ in range(6)]).records
        for r in recs:
            tl = r.timeline
            assert tl.admit <= tl.ship_done <= tl.device_done \
                <= tl.transfer_done <= tl.server_start <= tl.finish
            assert tl.server_wait >= 0

    def test_epoch_interval_batches_arrivals(self):
        """With a coarse decision epoch, staggered arrivals are priced as
        one window at the epoch boundary."""
        srv = stub_server()
        reqs = [req(arrival_time=t, segment_cached=True)
                for t in (0.1, 0.2, 0.3)]
        recs = FleetEngine(srv, epoch_interval=1.0).run(reqs).records
        assert all(r.timeline.admit == 1.0 for r in recs)
        # one shared window: later admissions see the epoch's queue
        assert recs[-1].queue_delay > 0


# ---------------------------------------------------------------------------
class TestSegmentCache:
    # offloading unattractive (10 MHz server, fast channel): device-side
    # plans (p > 0) win even for FRESH requests, so the model segment
    # really ships and the cache has something to hold
    def _slow_server(self):
        return ServerProfile(f_clock=1e7)

    def _stub(self):
        return stub_server(server=self._slow_server(), channel=Channel())

    def _req(self, **kw):
        return req(channel=Channel(), **kw)

    def test_repeat_requester_pays_activation_only(self):
        srv = self._stub()
        fleet = [self._slow_server()]
        first = self._req(device_id="phone-1")
        m1 = FleetEngine(srv, servers=fleet).run([first])
        rec1 = m1.records[0]
        assert rec1.deployment.plan.p > 0
        assert rec1.deployment.payload_bits == rec1.deployment.plan.payload_bits
        # repeat request AFTER the shipment finished downlinking
        later = rec1.timeline.ship_done + 1.0
        eng = FleetEngine(srv, servers=fleet)
        recs = eng.run([first,
                        dataclasses.replace(first, arrival_time=later),
                        dataclasses.replace(first, arrival_time=later,
                                            device_id="phone-2")]).records
        cached = recs[1].deployment
        fresh = recs[2].deployment
        assert cached.plan.p > 0
        assert cached.payload_bits == cached.plan.payload_x_bits
        assert cached.payload_bits < rec1.deployment.payload_bits
        # a different device has no cache: full payload again
        assert fresh.payload_bits == fresh.plan.payload_bits

    def test_caller_flag_ignored_with_device_id(self):
        """segment_cached=True from the caller must not grant a fresh
        device the activation-only price when the engine owns the cache."""
        srv = self._stub()
        r = self._req(device_id="phone-9", segment_cached=True)
        rec = FleetEngine(srv, servers=[self._slow_server()]).run([r]).records[0]
        assert rec.deployment.payload_bits == rec.deployment.plan.payload_bits

    def test_cache_installs_at_ship_done_not_admission(self):
        srv = self._stub()
        fleet = [self._slow_server()]
        first = self._req(device_id="phone-1")
        tl = FleetEngine(srv, servers=fleet).run([first]).records[0].timeline
        early = tl.ship_done * 0.5      # arrives mid-shipment
        recs = FleetEngine(srv, servers=fleet).run(
            [first, dataclasses.replace(first, arrival_time=early)]).records
        assert recs[1].deployment.payload_bits == \
            recs[1].deployment.plan.payload_bits


# ---------------------------------------------------------------------------
class TestSLOAdmission:
    def test_reject_infeasible_deadline(self):
        srv = stub_server()
        good, bad = req(deadline=1e4), req(deadline=1e-9)
        m = FleetEngine(srv, slo="reject").run([good, bad])
        assert not m.records[0].rejected
        assert m.records[1].rejected
        assert m.records[1].deployment is None
        assert m.records[1].deadline_missed is True
        assert m.deadline_miss_rate() == 0.5

    def test_observe_mode_never_rejects(self):
        srv = stub_server()
        m = FleetEngine(srv, slo="observe").run([req(deadline=1e-9)])
        assert not m.records[0].rejected
        assert m.records[0].deadline_missed is True

    def test_degrade_relaxes_budget_to_meet_deadline(self):
        srv = stub_server()
        # latency at the strictest vs coarsest accuracy level: the wire
        # payload shrinks with the budget, so coarser is faster
        strict = FleetEngine(srv).run(
            [req(min(srv.levels), segment_cached=True)]).records[0]
        coarse = FleetEngine(srv).run(
            [req(max(srv.levels), segment_cached=True)]).records[0]
        assert coarse.latency < strict.latency
        deadline = (coarse.latency + strict.latency) / 2
        rec = FleetEngine(srv, slo="degrade").run(
            [req(min(srv.levels), segment_cached=True,
                 deadline=deadline)]).records[0]
        assert not rec.rejected
        assert rec.degraded_to is not None
        assert rec.degraded_to > min(srv.levels)
        assert rec.latency <= deadline
        assert rec.deployment.extra["degraded_to"] == rec.degraded_to

    def test_degrade_rejects_when_nothing_fits(self):
        srv = stub_server()
        rec = FleetEngine(srv, slo="degrade").run(
            [req(deadline=1e-9)]).records[0]
        assert rec.rejected

    def test_least_loaded_falls_back_for_deadlines(self):
        """Rejection must mean 'every (server, candidate) pair misses':
        when the least-loaded server is too slow for the deadline, the
        dispatcher falls back to a faster one instead of rejecting."""
        srv = stub_server()
        slow, fast = ServerProfile(f_clock=1e6), ServerProfile(f_clock=6e9)
        r_slow = FleetEngine(srv, servers=[slow]).run([req()]).records[0]
        r_fast = FleetEngine(srv, servers=[fast]).run([req()]).records[0]
        deadline = (r_fast.latency + r_slow.latency) / 2
        rec = FleetEngine(srv, servers=[slow, fast], policy="least_loaded",
                          slo="reject").run(
            [req(deadline=deadline)]).records[0]
        assert not rec.rejected
        assert rec.server == 1
        assert rec.latency <= deadline


# ---------------------------------------------------------------------------
class TestFleet:
    def test_more_servers_cut_tail_latency(self):
        srv = stub_server()
        burst = [req(segment_cached=True) for _ in range(32)]
        one = FleetEngine(srv, servers=[ServerProfile()]).run(burst)
        three = FleetEngine(srv, servers=[ServerProfile()] * 3,
                            policy="least_loaded").run(burst)
        assert float(np.percentile(three.latencies(), 99)) < \
            float(np.percentile(one.latencies(), 99))
        # the dispatcher really spreads load
        assert len({r.server for r in three.records}) == 3

    def test_heterogeneous_fleet_prefers_faster_server(self):
        srv = stub_server()
        fast, slow = ServerProfile(f_clock=6e9), ServerProfile(f_clock=1e8)
        m = FleetEngine(srv, servers=[slow, fast]).run(
            [req(segment_cached=True)])
        assert m.records[0].server == 1

    def test_metrics_sanity(self):
        srv = stub_server()
        burst = [req(segment_cached=True, deadline=1e4) for _ in range(20)]
        m = FleetEngine(srv, servers=[ServerProfile()] * 2).run(burst)
        s = m.summary()
        assert s["requests"] == 20 and s["completed"] == 20
        assert s["rejected"] == 0 and s["deadline_miss_rate"] == 0.0
        assert s["p50_latency_s"] <= s["p99_latency_s"]
        assert all(0.0 <= u <= 1.0 for u in s["server_utilization"])
        assert s["max_queue_depth"] >= 1
        assert s["total_payload_bits"] > 0
        # every admitted request eventually completed: depth returns to 0
        assert m.queue_samples[-1][1] == 0

    def test_run_is_reentrant(self):
        """Each run() is an independent simulation: server queues and
        device caches must not leak from a previous trace."""
        srv = stub_server()
        eng = srv.fleet()
        trace = [req(segment_cached=True) for _ in range(5)]
        m1, m2 = eng.run(trace), eng.run(trace)
        assert m1.server_busy == m2.server_busy
        assert [r.deployment.objective for r in m1.records] == \
            [r.deployment.objective for r in m2.records]
        assert m1.records[0].queue_delay == m2.records[0].queue_delay == 0.0

    def test_empty_fleet_rejected(self):
        with pytest.raises(ValueError):
            stub_server().fleet(servers=[])

    def test_mixed_models_in_one_fleet_window(self):
        srv = stub_server(configs=(("mnist", MNIST_MLP),
                                   ("cifar", CIFAR_CNN)))
        reqs = [InferenceRequest("mnist" if i % 2 else "cifar", 0.01,
                                 DEV, CH, W, segment_cached=True)
                for i in range(8)]
        recs = FleetEngine(srv).run(reqs).records
        assert [r.request for r in recs] == reqs
        assert all(r.deployment is not None for r in recs)


# ---------------------------------------------------------------------------
class TestProviderFleet:
    """CostModel v2 in the engine: a non-default provider re-prices
    admission AND the wall-clock reservation timelines."""

    def test_roofline_provider_prices_memory_into_stages(self):
        from repro.core.cost_model import (AnalyticCost, RooflineCost,
                                           plan_cost_terms)
        srv = stub_server()
        recs = FleetEngine(srv, provider=RooflineCost()).run(
            [req(segment_cached=True) for _ in range(4)]).records
        ana = AnalyticCost()
        for r in recs:
            dep = r.deployment
            assert dep is not None
            specs = dep.backend.layer_specs(batch=dep.request.batch)
            o1, o2, _db, _sb = plan_cost_terms(dep.plan, specs)
            # stage times are the roofline ones: compute + memory
            assert dep.costs.t_local >= float(
                ana.device_seconds(dep.request.device, o1)) - 1e-18
            assert dep.costs.t_server >= float(
                ana.server_seconds(srv.server, o2)) - 1e-18

    def test_calibrated_provider_reprices_reservations(self):
        """The second simultaneous request's priced backlog must be the
        FIRST deployment's server seconds AT THE CALIBRATED RATE — the
        reservation timeline runs on the provider's clock."""
        from repro.core.cost_model import (CalibratedCost, StageRates,
                                           plan_cost_terms)
        srv = stub_server()
        cal = CalibratedCost({}, {}, StageRates(1e-7, 0.0, 0.0),
                             StageRates(1e-6, 0.0, 0.0))
        recs = FleetEngine(srv, provider=cal).run(
            [req(segment_cached=True), req(segment_cached=True)]).records
        first = recs[0].deployment
        specs = first.backend.layer_specs(batch=first.request.batch)
        _o1, o2, _db, sb = plan_cost_terms(first.plan, specs)
        expect = float(cal.server_seconds(srv.server, o2, sb))
        assert first.costs.t_server == pytest.approx(expect, rel=1e-12)
        if o2 > 0:
            assert recs[1].backlog_at_admission == pytest.approx(
                expect, rel=1e-12)

    def test_engine_inherits_server_provider(self):
        from repro.core.cost_model import RooflineCost
        srv = stub_server()
        srv.provider = RooflineCost()
        assert FleetEngine(srv).provider is srv.provider


# ---------------------------------------------------------------------------
class TestTotalLatency:
    def test_accepts_serve_batch_results(self):
        """Satellite fix: serve/serve_batch results carry no queue_delay
        — total_latency must read it as 0, not raise KeyError."""
        srv = stub_server()
        deps = srv.serve_batch([req(segment_cached=True) for _ in range(4)])
        t = total_latency(deps)
        assert t == pytest.approx(sum(d.costs.t_total for d in deps))
        assert all(d.queue_delay == 0.0 for d in deps)

    def test_counts_queue_delay_when_present(self):
        srv = stub_server()
        out = WorkloadBalancer(ServerProfile()).schedule(
            srv, [req(segment_cached=True) for _ in range(6)])
        assert total_latency(out) > sum(sr.result.costs.t_total
                                        for sr in out)


# ---------------------------------------------------------------------------
class TestScaleKnobs:
    """§12 satellites: the degrade-ladder re-price cache and the hoisted
    least_loaded server ordering never change a decision."""

    def _degrade_trace(self, srv, n=40):
        # deadlines straddling the strict/coarse latency split: a chunk
        # of the trace walks the degrade ladder through _reprice_single
        strict = FleetEngine(srv).run(
            [req(min(srv.levels), segment_cached=True)]).records[0]
        coarse = FleetEngine(srv).run(
            [req(max(srv.levels), segment_cached=True)]).records[0]
        deadline = (coarse.latency + strict.latency) / 2
        # cached requests price p > 0 candidates (payload shrinks with
        # the budget) — the regime where relaxing the budget can rescue
        # a deadline instead of just rejecting
        return [req(min(srv.levels), segment_cached=True,
                    deadline=deadline * (1 + 0.5 * (i % 3)),
                    arrival_time=i * 0.0007) for i in range(n)]

    def test_reprice_cache_matches_uncached(self):
        srv = stub_server()
        trace = self._degrade_trace(srv)
        runs = {}
        for cached in (True, False):
            m = FleetEngine(srv, servers=[ServerProfile()] * 2,
                            slo="degrade", epoch_interval=0.005,
                            reprice_cache=cached).run(trace)
            runs[cached] = m
        a, b = runs[True], runs[False]
        assert a.journal.diff(b.journal) is None
        assert a.summary() == b.summary()
        # some requests really degraded, so the ladder actually re-priced
        assert a.summary()["degraded"] > 0
        obj_on = np.array([r.deployment.objective for r in a.completed()])
        obj_off = np.array([r.deployment.objective for r in b.completed()])
        assert np.array_equal(obj_on, obj_off)

    def test_least_loaded_hoisted_order_unchanged(self):
        """The once-per-backlog-change server ordering (vectorized path)
        admits exactly what the per-request re-sort (reference path)
        admits, on a loaded heterogeneous fleet."""
        srv = stub_server()
        fleet = [ServerProfile(), ServerProfile(f_clock=4e9),
                 ServerProfile()]
        trace = [req(0.01 if i % 2 else 0.004, deadline=0.5,
                     arrival_time=i * 0.0004, device_id=f"d{i % 5}")
                 for i in range(60)]
        runs = [FleetEngine(srv, servers=fleet, policy="least_loaded",
                            slo="degrade", epoch_interval=0.003,
                            admission=mode).run(trace)
                for mode in ("vectorized", "reference")]
        assert runs[0].journal.diff(runs[1].journal) is None
        assert runs[0].summary() == runs[1].summary()
        # the trace spread load: both servers of equal speed got work
        servers_used = {r.server for r in runs[0].completed()}
        assert len(servers_used) > 1


# ---------------------------------------------------------------------------
class TestPolicyOrdering:
    """Property-style ordering guarantees (hypothesis; deterministic
    shim skips when hypothesis is absent)."""

    @given(st.lists(st.tuples(st.sampled_from([1.0, 2.0, 5.0, 10.0]),
                              st.booleans()),
                    min_size=2, max_size=10),
           st.sampled_from([0.004, 0.01, 0.02]))
    @settings(max_examples=25, deadline=None, derandomize=True)
    def test_balanced_never_worse_than_fcfs(self, speeds, budget):
        srv = _PROPERTY_SERVER
        reqs = [req(budget, device=dataclasses.replace(DEV,
                                                       f_clock=DEV.f_clock * s),
                    segment_cached=cached)
                for s, cached in speeds]
        t_f = total_latency(WorkloadBalancer(
            ServerProfile(), policy="fcfs").schedule(srv, reqs))
        t_b = total_latency(WorkloadBalancer(
            ServerProfile(), policy="balanced").schedule(srv, reqs))
        assert t_b <= t_f * (1 + 1e-9)

    @given(st.lists(st.floats(min_value=0.01, max_value=10.0),
                    min_size=2, max_size=12))
    @settings(max_examples=25, deadline=None, derandomize=True)
    def test_edf_meets_whatever_fcfs_meets(self, deadlines):
        """Jackson's rule on identical requests: whenever FCFS meets
        every deadline of a trace, EDF meets them all too, and EDF's
        worst lateness never exceeds FCFS's."""
        srv = _PROPERTY_SERVER
        reqs = [req(segment_cached=True, deadline=d) for d in deadlines]

        def lateness(policy):
            m = FleetEngine(srv, policy=policy).run(reqs)
            return [r.latency - r.request.deadline for r in m.records]

        late_f, late_e = lateness("fcfs"), lateness("edf")
        assert max(late_e) <= max(late_f) + 1e-9
        if max(late_f) <= 0:
            assert max(late_e) <= 0


# built once at import: hypothesis re-runs the test body many times and
# the store is read-only under pricing
_PROPERTY_SERVER = stub_server()
