"""Sharding-rule metadata tests: every parameter / cache / batch spec must
(1) cover the exact tree structure and (2) request only divisible shards —
the invariant that made the 40x2-mesh dry-run pass. Pure metadata: no
multi-device mesh is created here (smoke env has one CPU device)."""
import dataclasses

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ASSIGNED_ARCHS, INPUT_SHAPES, for_shape, get_config
from repro.launch import sharding as shard_lib
from repro.launch.mesh import MODEL_AXIS
from repro.launch.steps import batch_specs, cache_specs, param_specs

pytestmark = pytest.mark.smoke

MODEL_SIZE = 16            # production model-axis extent


class FakeMesh:
    """Just enough mesh interface for the pspec builders."""
    axis_names = ("data", "model")
    shape = {"data": 16, "model": MODEL_SIZE}


def _check_divisible(specs, pspecs, msize=MODEL_SIZE, dsize=16):
    leaves_s = jax.tree.leaves(specs)
    leaves_p = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves_s) == len(leaves_p)
    for sds, spec in zip(leaves_s, leaves_p):
        for dim, axis in enumerate(spec):
            if axis is None:
                continue
            size = msize if axis == MODEL_AXIS else dsize
            if isinstance(axis, tuple):
                size = int(np.prod([
                    msize if a == MODEL_AXIS else dsize for a in axis]))
            assert sds.shape[dim] % size == 0, \
                (sds.shape, spec, dim, axis)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_pspecs_cover_and_divide(arch):
    cfg = get_config(arch)
    p = param_specs(cfg)
    specs = shard_lib.param_pspecs(cfg, p, mesh=FakeMesh())
    _check_divisible(p, specs)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_pspecs_cover_and_divide(arch, shape_name):
    cfg = for_shape(get_config(arch), INPUT_SHAPES[shape_name])
    shape = INPUT_SHAPES[shape_name]
    c = cache_specs(cfg, shape.global_batch, shape.seq_len)
    specs = shard_lib.cache_pspecs(cfg, c, FakeMesh(), shape.global_batch)
    _check_divisible(c, specs)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_batch_pspecs(arch):
    cfg = get_config(arch)
    shape = INPUT_SHAPES["train_4k"]
    b = batch_specs(cfg, shape)
    specs = shard_lib.batch_pspecs(
        FakeMesh(), shape.global_batch,
        has_embeds="embeds" in b, has_positions="positions" in b)
    assert set(specs) == set(b)
    _check_divisible(b, {k: specs[k] for k in b})


def test_fsdp_only_adds_data_axis():
    cfg = get_config("qwen2-vl-72b")
    p = param_specs(cfg)
    base = shard_lib.param_pspecs(cfg, p, mesh=FakeMesh())
    fsdp = shard_lib.param_pspecs(cfg, p, fsdp=True, mesh=FakeMesh())
    for b, f, leaf in zip(jax.tree.leaves(base, is_leaf=lambda x: isinstance(x, P)),
                          jax.tree.leaves(fsdp, is_leaf=lambda x: isinstance(x, P)),
                          jax.tree.leaves(p)):
        # fsdp spec must keep every model-axis assignment of the base spec
        bl = list(b) + [None] * (leaf.ndim - len(b))
        fl = list(f) + [None] * (leaf.ndim - len(f))
        for d in range(leaf.ndim):
            if bl[d] is not None:
                assert fl[d] == bl[d]
            if fl[d] is not None and bl[d] is None:
                assert leaf.shape[d] % 16 == 0


def test_padded_heads_always_divisible():
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        if not cfg.num_heads:
            continue
        kvp, gp = cfg.padded_heads()
        assert (kvp * gp) % cfg.tp_pad == 0
        assert kvp >= cfg.num_kv_heads
        assert gp >= cfg.num_heads // cfg.num_kv_heads


def test_padded_vocab_divisible():
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        assert cfg.padded_vocab() % cfg.tp_pad == 0
        assert cfg.padded_vocab() >= cfg.vocab_size
        assert cfg.padded_vocab() - cfg.vocab_size < cfg.tp_pad


def test_long_500k_subquadratic_for_all():
    """Dense/MoE archs must pick up a sliding window for long_500k; SSM
    and hybrid run natively (DESIGN.md §4)."""
    shape = INPUT_SHAPES["long_500k"]
    for arch in ASSIGNED_ARCHS:
        cfg = for_shape(get_config(arch), shape)
        if cfg.attn_every >= 1:
            assert cfg.sliding_window is not None
            assert cfg.sliding_window < shape.seq_len


@pytest.mark.parametrize("bits", [8, 4])
def test_quantized_param_pspecs_cover_and_divide(bits):
    """int8/int4 serving trees: codes shard like their weight, scale/mu
    replicate, and every sharded dim still divides the mesh."""
    import jax.numpy as jnp
    from repro.core.quantizer import quantize_params_for_serving
    cfg = get_config("qwen3-14b")
    p = param_specs(cfg)
    qp = jax.eval_shape(lambda pp: quantize_params_for_serving(pp, bits), p)
    specs = shard_lib.param_pspecs(cfg, qp, mesh=FakeMesh())
    _check_divisible(qp, specs)
