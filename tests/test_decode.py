"""Autoregressive decode serving (DESIGN.md §11): segment prefill/decode
parity against the monolithic forward family (attention AND SSM blocks),
``DecodeSession`` greedy streams across cut points on ONE set of jitted
programs (compile-once), the KV-cache dtype/footprint contract for
quantized device segments, per-token pricing rows, KV-aware feasibility,
``Deployment.generate`` → ledger, and the fleet engine's continuous-
batching decode lane (metrics keys, chaos severance, replay)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.configs.classifier import MNIST_MLP
from repro.core.cost_model import Channel, DeviceProfile, ObjectiveWeights
from repro.core.solver import PartitionPlan
from repro.models import transformer as T
from repro.serving.backends import TransformerBackend
from repro.serving.decode import (DecodeSession, kv_cache_dtype,
                                  segment_cache_bytes)
from repro.serving.engine import FleetEngine
from repro.serving.engine.faults import (DISCONNECT, RECONNECT, FaultEvent)
from repro.serving.errors import ServingError
from repro.serving.pricing import decode_rows_for
from repro.serving.qpart_server import QPARTServer
from repro.serving.simulator import InferenceRequest
from repro.serving.testing import (stub_calibration,
                                   stub_transformer_calibration)

pytestmark = pytest.mark.smoke

KEY = jax.random.key(0)
SEQ = 16
MAX_LEN = 48


def _f32(cfg):
    return dataclasses.replace(cfg, dtype="float32")


def _manual_plan(p: int, bits: float = 16.0) -> PartitionPlan:
    return PartitionPlan(p=p, bits_w=np.full(p, float(bits)),
                         bits_x=float(bits), objective=0.0, psi_total=0.0,
                         payload_bits=0.0, breakdown={})


@pytest.fixture(scope="module", params=["smollm-135m", "mamba2-1.3b"],
                ids=["attn", "ssm"])
def family(request):
    cfg = _f32(get_config(request.param).reduced())
    return cfg, T.init_params(KEY, cfg)


@pytest.fixture(scope="module")
def lm():
    """Tiny trained-free smollm: untrained params are fine — parity is a
    numerical property, not an accuracy one."""
    cfg = dataclasses.replace(
        get_config("smollm-135m").reduced(), name="smollm-decode",
        d_model=64, num_heads=2, num_kv_heads=1, head_dim=32, d_ff=128,
        vocab_size=32, tp_pad=1, dtype="float32")
    return cfg, T.init_params(KEY, cfg)


class TestSegmentParity:
    """segment_prefill / segment_decode_step == the monolithic prefill /
    decode_step, for both block families, bit for bit."""

    def test_full_segment_prefill_matches_prefill(self, family):
        cfg, params = family
        toks = jax.random.randint(KEY, (2, SEQ), 0, cfg.vocab_size)
        lg_ref, caches_ref, _ = T.prefill(params, cfg, toks, max_len=MAX_LEN,
                                          cache_dtype=jnp.float32)
        h0 = T.embed_tokens(params, cfg, toks)
        cache0 = T.init_cache(cfg, 2, MAX_LEN, jnp.float32)
        h, caches = T.segment_prefill(params, cfg, h0, cache0, 0,
                                      cfg.num_layers)
        lg = T.unembed(params, cfg, h)
        np.testing.assert_array_equal(np.asarray(lg), np.asarray(lg_ref))
        for a, b in zip(jax.tree.leaves(caches),
                        jax.tree.leaves(caches_ref)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_split_prefill_matches_monolithic(self, family):
        cfg, params = family
        L = cfg.num_layers
        p = L // 2
        toks = jax.random.randint(KEY, (2, SEQ), 0, cfg.vocab_size)
        h0 = T.embed_tokens(params, cfg, toks)
        cache0 = T.init_cache(cfg, 2, MAX_LEN, jnp.float32)
        h_ref, _ = T.segment_prefill(params, cfg, h0, cache0, 0, L)
        h_dev, _ = T.segment_prefill(params, cfg, h0,
                                     T.init_cache(cfg, 2, MAX_LEN,
                                                  jnp.float32), 0, p)
        h_srv, _ = T.segment_prefill(params, cfg, h_dev,
                                     T.init_cache(cfg, 2, MAX_LEN,
                                                  jnp.float32), p, L)
        np.testing.assert_array_equal(np.asarray(h_srv), np.asarray(h_ref))

    def test_segment_decode_step_matches_decode_step(self, family):
        cfg, params = family
        toks = jax.random.randint(KEY, (2, SEQ), 0, cfg.vocab_size)
        lg, caches, _ = T.prefill(params, cfg, toks, max_len=MAX_LEN,
                                  cache_dtype=jnp.float32)
        nxt = jnp.argmax(lg[:, -1:], -1).astype(jnp.int32)
        pos = jnp.asarray(SEQ, jnp.int32)
        lg_ref, _ = T.decode_step(params, cfg, nxt, caches, pos)
        x = T.embed_tokens(params, cfg, nxt)
        x_out, _ = T.segment_decode_step(params, cfg, x, caches, pos, 0,
                                         cfg.num_layers)
        lg_seg = T.unembed(params, cfg, x_out)
        np.testing.assert_array_equal(np.asarray(lg_seg[:, 0]),
                                      np.asarray(lg_ref[:, 0]))


class TestDecodeSession:
    def _greedy_reference(self, cfg, params, prompt, n):
        """Teacher-forced greedy reference via the full forward."""
        toks = jnp.asarray(prompt, jnp.int32)
        out = []
        for _ in range(n):
            lg, _ = T.forward(params, cfg, toks)
            nxt = jnp.argmax(lg[:, -1:], -1).astype(jnp.int32)
            out.append(np.asarray(nxt[:, 0]))
            toks = jnp.concatenate([toks, nxt], axis=1)
        return np.stack(out, axis=1)

    def test_full_offload_matches_forward_greedy(self, lm):
        cfg, params = lm
        backend = TransformerBackend(cfg, params, seq_len=SEQ)
        prompt = jax.random.randint(KEY, (2, SEQ), 0, cfg.vocab_size)
        sess = DecodeSession(backend, _manual_plan(0), max_len=MAX_LEN)
        out = sess.generate(prompt, 6)
        ref = self._greedy_reference(cfg, params, prompt, 6)
        np.testing.assert_array_equal(out.tokens, ref)
        assert out.ttft_s > 0 and len(out.per_token_s) == 5
        assert out.device_cache_bytes == 0          # nothing resides on-device

    def test_cuts_agree_and_compile_once(self, lm):
        """Every cut point produces the p=0 greedy stream at fp bit-
        widths, on a CONSTANT jitted-program count after the first cut
        (dynamic (start, stop, pos) — the compile-once contract)."""
        cfg, params = lm
        backend = TransformerBackend(cfg, params, seq_len=SEQ)
        prompt = jax.random.randint(KEY, (2, SEQ), 0, cfg.vocab_size)
        L = cfg.num_layers
        ref = DecodeSession(backend, _manual_plan(0),
                            max_len=MAX_LEN).generate(prompt, 6).tokens
        first_cut = DecodeSession(backend, _manual_plan(1),
                                  max_len=MAX_LEN).generate(prompt, 6)
        np.testing.assert_array_equal(first_cut.tokens, ref)
        traces = backend.trace_count
        for p in (L // 2, L):
            out = DecodeSession(backend, _manual_plan(p),
                                max_len=MAX_LEN).generate(prompt, 6)
            np.testing.assert_array_equal(out.tokens, ref)
        assert backend.trace_count == traces, \
            "decode programs re-traced across cut points"

    def test_quantized_cache_dtype_and_footprint(self, lm):
        """Satellite 4: a quantized device segment holds its KV cache in
        the deployed bit-width's storage dtype — 8-bit → float8 at HALF
        the bf16 footprint, no silent upcast to the model dtype."""
        cfg, params = lm
        backend = TransformerBackend(cfg, params, seq_len=SEQ)
        prompt = jax.random.randint(KEY, (2, SEQ), 0, cfg.vocab_size)
        p = cfg.num_layers // 2
        lo = DecodeSession(backend, _manual_plan(p, bits=8.0),
                           max_len=MAX_LEN)
        hi = DecodeSession(backend, _manual_plan(p, bits=16.0),
                           max_len=MAX_LEN)
        assert lo.dev_dtype == jnp.float8_e4m3fn
        assert hi.dev_dtype == jnp.bfloat16
        out_lo = lo.generate(prompt, 4)
        out_hi = hi.generate(prompt, 4)
        assert out_lo.device_cache_dtype == "float8_e4m3fn"
        # footprint assertion: every device-cache leaf really is stored
        # at the narrow dtype (nbytes halves vs the bf16 cache)
        assert out_lo.device_cache_bytes * 2 == out_hi.device_cache_bytes
        for leaf in jax.tree.leaves(lo.dev_caches):
            assert leaf.dtype in (jnp.float8_e4m3fn, jnp.float32), leaf.dtype
        # tokens stay valid ids (low-bit streams may diverge from fp)
        assert out_lo.tokens.min() >= 0
        assert out_lo.tokens.max() < cfg.vocab_size

    def test_dtype_ladder(self):
        assert kv_cache_dtype(6) == jnp.float8_e4m3fn
        assert kv_cache_dtype(8) == jnp.float8_e4m3fn
        assert kv_cache_dtype(12) == jnp.bfloat16
        assert kv_cache_dtype(16) == jnp.bfloat16
        assert kv_cache_dtype(32, jnp.float32) == jnp.float32
        assert kv_cache_dtype(0, jnp.float32) == jnp.float32

    def test_prompt_overflow_raises(self, lm):
        cfg, params = lm
        backend = TransformerBackend(cfg, params, seq_len=SEQ)
        sess = DecodeSession(backend, _manual_plan(0), max_len=SEQ)
        prompt = jnp.zeros((1, SEQ), jnp.int32)
        with pytest.raises(ServingError, match="no room"):
            sess.prefill(prompt)


class TestDecodePricing:
    def _server(self, decode_max_len=64):
        cfg = _f32(get_config("smollm-135m").reduced())
        dev = DeviceProfile(memory_bytes=2e9)
        ch = Channel(capacity_bps=2e6)
        w = ObjectiveWeights()
        srv = QPARTServer()
        stub_transformer_calibration(srv, "lm", cfg, dev, ch, w,
                                     seq_len=SEQ,
                                     decode_max_len=decode_max_len)
        return srv, cfg, (dev, ch, w)

    def test_decode_rows_shape_and_monotonicity(self):
        srv, cfg, (dev, ch, w) = self._server()
        m = srv.models["lm"]
        rows = decode_rows_for(m.backend, m.store(None), m.store(None)
                               .level_for(0.05), 1, need_bytes=True)
        L = cfg.num_layers
        assert rows.o1.shape == (L + 1,)
        assert np.all(np.diff(rows.o1) > 0)          # per-token MACs cumulate
        assert np.all(np.diff(rows.o2) < 0)
        assert rows.dev_bytes is not None and rows.srv_bytes is not None
        # decode KV traffic scales with context, so per-token device
        # bytes dwarf the per-token MAC count's naive 2-byte estimate
        assert rows.dev_bytes[L] > 0

    def test_kv_footprint_prunes_candidates(self):
        """A decode-planned backend adds the max_len KV footprint to the
        feasibility mask: a device that fits the quantized weights but
        NOT weights + cache must fall back to smaller p / full offload."""
        srv, cfg, (dev, ch, w) = self._server(decode_max_len=64)
        kv_row = srv.models["lm"].backend.kv_bytes_row(1)
        assert kv_row is not None and kv_row[-1] > 0
        store = srv.models["lm"].store(None)
        lv = store.level_for(0.05)
        mem = store.level_memory_rows(lv)
        # budget that admits every candidate's WEIGHTS but not the full
        # cache at the deepest cuts
        budget = float(mem[-1]) + float(kv_row[-1]) * 0.5
        tight = dataclasses.replace(dev, memory_bytes=budget)
        dep = srv.serve(InferenceRequest("lm", 0.05, tight, ch, w))
        assert dep.plan.device_memory_bytes + kv_row[dep.plan.p] <= budget
        infeasible = [p for p in range(cfg.num_layers + 1)
                      if float(mem[p]) + float(kv_row[p]) > budget]
        assert dep.plan.p not in infeasible and infeasible

    def test_prefill_only_pricing_unchanged(self):
        """decode_max_len=None backends price bit-identically to the
        pre-decode engine: kv_bytes_row is None, no mask change."""
        srv, cfg, (dev, ch, w) = self._server(decode_max_len=None)
        assert srv.models["lm"].backend.kv_bytes_row(1) is None
        dep = srv.serve(InferenceRequest("lm", 0.05, dev, ch, w))
        assert dep.plan.objective > 0


class TestDeploymentGenerate:
    @pytest.fixture(scope="class")
    def served(self, lm):
        cfg, params = lm
        srv = QPARTServer()
        backend = TransformerBackend(cfg, params, seq_len=SEQ,
                                     decode_max_len=MAX_LEN)
        toks = np.asarray(jax.random.randint(KEY, (8, SEQ), 0,
                                             cfg.vocab_size))
        srv.register("lm", backend, toks, np.zeros(8, np.int32))
        m = srv.models["lm"]
        L = cfg.num_layers
        m.s_w, m.s_x, m.rho = (np.ones(L), np.ones(L), np.full(L, 0.1))
        m.delta_table = {a: a * 50 for a in srv.levels}
        dev = DeviceProfile(memory_bytes=2e9)
        ch = Channel(capacity_bps=2e6)
        w = ObjectiveWeights()
        srv.build_store("lm", dev, ch, w)
        return srv, (dev, ch, w)

    def test_generate_streams_and_feeds_ledger(self, served):
        srv, (dev, ch, w) = served
        dep = srv.serve(InferenceRequest("lm", 0.05, dev, ch, w))
        seen = []
        prompt = np.zeros((1, 8), np.int32)
        out = dep.generate(prompt, 5, stream_cb=lambda i, t: seen.append(i))
        assert seen == [0, 1, 2, 3, 4]
        assert out.tokens.shape == (1, 5)
        meas = dep.result.extra["measured_decode"]
        assert meas["new_tokens"] == 5 and meas["tokens_per_s"] > 0
        n0 = len(srv.ledger.samples)
        srv.record_decode(dep)
        assert len(srv.ledger.samples) == n0 + 1

    def test_session_rejects_classifier_backend(self):
        from repro.models.classifier import init_classifier
        from repro.serving.backends import ClassifierBackend
        params = init_classifier(KEY, MNIST_MLP)
        backend = ClassifierBackend(MNIST_MLP, params)
        with pytest.raises(ServingError, match="decode"):
            DecodeSession(backend, _manual_plan(0), max_len=8)


class TestFleetDecode:
    def _stub(self, decode_max_len=64):
        cfg = _f32(get_config("smollm-135m").reduced())
        dev = DeviceProfile(memory_bytes=2e9)
        ch = Channel(capacity_bps=2e6)
        w = ObjectiveWeights()
        srv = QPARTServer()
        stub_transformer_calibration(srv, "lm", cfg, dev, ch, w,
                                     seq_len=SEQ,
                                     decode_max_len=decode_max_len)
        return srv, (dev, ch, w)

    def test_streams_complete_with_metrics(self):
        srv, (dev, ch, w) = self._stub()
        reqs = [InferenceRequest("lm", 0.05, dev, ch, w,
                                 arrival_time=0.0, device_id=f"d{i}",
                                 max_new_tokens=30) for i in range(6)]
        reqs.append(InferenceRequest("lm", 0.05, dev, ch, w,
                                     arrival_time=0.01, device_id="d9"))
        metrics = FleetEngine(srv).run(reqs)
        metrics.assert_terminal()
        s = metrics.summary()
        assert s["completed"] == 7
        assert s["tokens_per_s"] > 0
        assert s["ttft_p50"] is not None and s["ttft_p99"] >= s["ttft_p50"]
        for r in metrics.records[:6]:
            assert r.tokens_emitted == 30
            assert r.decode_done is not None
            assert r.latency > r.ttft          # the stream outlives TTFT
        assert metrics.records[6].decode_tokens == 0
        # decode rounds really batched: fewer rounds than request-tokens
        rounds = [e for e in metrics.journal.entries
                  if e.kind == "decode_step" and not dict(e.data)["stale"]]
        assert rounds and any(dict(e.data)["batch"] > 1 for e in rounds)
        metrics.journal.verify_replay(srv, reqs)

    def test_midstream_disconnect_severs_and_retries(self):
        srv, (dev, ch, w) = self._stub()
        reqs = [InferenceRequest("lm", 0.05, dev, ch, w, arrival_time=0.0,
                                 device_id="d0", max_new_tokens=50),
                InferenceRequest("lm", 0.05, dev, ch, w, arrival_time=0.0,
                                 device_id="d1", max_new_tokens=50)]
        horizon = FleetEngine(srv).run(reqs).horizon
        faults = [FaultEvent(horizon / 2, DISCONNECT, "d0"),
                  FaultEvent(horizon, RECONNECT, "d0")]
        metrics = FleetEngine(srv, faults=faults).run(reqs)
        metrics.assert_terminal()
        r0 = metrics.records[0]
        assert r0.faults == 1 and r0.attempts == 2 and not r0.rejected
        assert r0.tokens_emitted == r0.decode_tokens == 50
        assert metrics.records[1].faults == 0
        assert metrics.records[1].tokens_emitted == 50
        metrics.journal.verify_replay(srv, reqs)

    def test_decode_on_classifier_raises(self):
        srv = QPARTServer()
        dev = DeviceProfile()
        ch = Channel(capacity_bps=2e6)
        w = ObjectiveWeights()
        stub_calibration(srv, "clf", MNIST_MLP, dev, ch, w)
        req = InferenceRequest("clf", 0.05, dev, ch, w, max_new_tokens=4)
        with pytest.raises(ServingError, match="decode"):
            FleetEngine(srv).run([req])

    def test_zero_decode_trace_bit_identical(self):
        """max_new_tokens=0 traces through a decode-planned backend are
        decode-lane-free: no DECODE_STEP entries, zeroed decode metrics."""
        srv, (dev, ch, w) = self._stub()
        reqs = [InferenceRequest("lm", 0.05, dev, ch, w,
                                 arrival_time=0.02 * i, device_id=f"d{i}")
                for i in range(4)]
        metrics = FleetEngine(srv).run(reqs)
        metrics.assert_terminal()
        assert all(e.kind != "decode_step" for e in metrics.journal.entries)
        s = metrics.summary()
        assert s["tokens_per_s"] == 0.0
        assert all(r.decode_tokens == 0 for r in metrics.records)


class TestDecodeBatcher:
    """Observable semantics of the heap-backed continuous-batching state
    (PR 9) — locked against the pre-heap linear-scan implementation:
    ``due`` yields joiners in ADMISSION order, ``next_time`` is
    ``max(busy_until, min live ready_at)``, re-arms never reorder."""

    @staticmethod
    def _stream(index, ready_at):
        from repro.serving.decode.batching import DecodeStream
        return DecodeStream(index=index, token=(index, 1), device_id=None,
                            remaining=4, ready_at=ready_at, o2_tok=1.0,
                            srv_bytes_tok=1.0, step_lag=0.1)

    def _batcher(self):
        from repro.serving.decode.batching import DecodeBatcher
        return DecodeBatcher()

    def test_due_admission_order(self):
        b = self._batcher()
        for i, r in [(9, 0.5), (1, 0.2), (5, 0.9)]:
            b.add(self._stream(i, r))
        assert [s.index for s in b.due(1.0)] == [9, 1, 5]
        assert [s.index for s in b.due(0.3)] == [1]
        assert [s.index for s in b.due(0.6)] == [9, 1]

    def test_rearm_keeps_admission_order(self):
        b = self._batcher()
        b.add(self._stream(1, 0.0))
        b.add(self._stream(2, 0.0))
        b.rearm(1, 5.0)                      # later ready, same seat
        assert [s.index for s in b.due(10.0)] == [1, 2]
        assert b.streams[1].ready_at == 5.0
        assert [s.index for s in b.due(1.0)] == [2]

    def test_next_time_max_of_busy_and_min_ready(self):
        b = self._batcher()
        assert b.next_time() is None
        b.add(self._stream(1, 3.0))
        b.add(self._stream(2, 7.0))
        assert b.next_time() == 3.0
        b.busy_until = 4.5
        assert b.next_time() == 4.5
        b.rearm(1, 9.0)                      # stale heap top is skipped
        assert b.next_time() == 7.0

    def test_remove_then_readmit_enters_at_back(self):
        b = self._batcher()
        for i in (1, 2, 3):
            b.add(self._stream(i, 0.0))
        b.remove(1)
        assert [s.index for s in b.due(1.0)] == [2, 3]
        b.add(self._stream(1, 0.0))          # fresh admission → back
        assert [s.index for s in b.due(1.0)] == [2, 3, 1]

    def test_overwrite_add_keeps_seat(self):
        b = self._batcher()
        b.add(self._stream(1, 0.0))
        b.add(self._stream(2, 0.0))
        b.add(self._stream(1, 0.4))          # retry overwrite, same seat
        assert [s.index for s in b.due(1.0)] == [1, 2]
        assert b.streams[1].ready_at == 0.4

    def test_remove_clears_next_time(self):
        b = self._batcher()
        b.add(self._stream(1, 2.0))
        b.remove(1)
        assert b.next_time() is None
        assert b.due(10.0) == []
