"""Block-granular KV allocation (PR 9, DESIGN.md §13): the paged cache
round-trips the dense ring bit-for-bit at every cut, the resident
footprint is page-monotone and strictly under the worst-case
reservation, severed streams leak nothing (live sessions AND a seeded
fleet trace with mid-stream disconnects), and page-rounded admission
admits stream configs the ``decode_max_len`` worst-case mask rejects."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.cost_model import Channel, DeviceProfile, ObjectiveWeights
from repro.core.solver import PartitionPlan
from repro.models import transformer as T
from repro.serving.backends import TransformerBackend
from repro.serving.decode import DecodeSession
from repro.serving.decode.cache import (DEFAULT_PAGE_TOKENS, KVPagePool,
                                        PagedKVCache, PageLedger,
                                        paged_kv_ctx, segment_page_pool)
from repro.serving.engine import FleetEngine
from repro.serving.engine.faults import DISCONNECT, RECONNECT, FaultEvent
from repro.serving.errors import ServingError
from repro.serving.pricing import price_window
from repro.serving.qpart_server import QPARTServer
from repro.serving.simulator import InferenceRequest
from repro.serving.testing import stub_transformer_calibration

pytestmark = pytest.mark.smoke

KEY = jax.random.key(0)
SEQ = 16
MAX_LEN = 48
PAGE = 8


def _manual_plan(p: int, bits: float = 16.0) -> PartitionPlan:
    return PartitionPlan(p=p, bits_w=np.full(p, float(bits)),
                         bits_x=float(bits), objective=0.0, psi_total=0.0,
                         payload_bits=0.0, breakdown={})


@pytest.fixture(scope="module")
def lm():
    cfg = dataclasses.replace(
        get_config("smollm-135m").reduced(), name="smollm-paged",
        d_model=64, num_heads=2, num_kv_heads=1, head_dim=32, d_ff=128,
        vocab_size=32, tp_pad=1, dtype="float32")
    return cfg, T.init_params(KEY, cfg)


class TestPagedKVCtx:
    def test_rounds_up_to_page_and_caps_at_max(self):
        assert paged_kv_ctx(1, 16, 64) == 16
        assert paged_kv_ctx(16, 16, 64) == 16
        assert paged_kv_ctx(17, 16, 64) == 32
        assert paged_kv_ctx(1000, 16, 64) == 64

    def test_never_exceeds_dense_worst_case(self):
        for tokens in range(1, 200, 7):
            assert paged_kv_ctx(tokens, 16, 64) <= 64

    def test_monotone_in_tokens(self):
        ctxs = [paged_kv_ctx(t, 16, 64) for t in range(1, 128)]
        assert all(a <= b for a, b in zip(ctxs, ctxs[1:]))


class TestPagePool:
    def test_alloc_release_and_exhaustion(self):
        pool = KVPagePool(2, 4, kvp=1, hd=8, dtype=jnp.float32)
        a, b = pool.alloc(), pool.alloc()
        assert pool.used_pages == 2
        assert pool.used_bytes == 2 * pool.page_bytes
        with pytest.raises(ServingError, match="exhausted"):
            pool.alloc()
        pool.release(a)
        assert pool.used_pages == 1
        c = pool.alloc()                 # recycled
        assert c == a
        pool.release(b)
        pool.release(c)
        assert pool.used_pages == 0

    def test_alloc_zeroes_recycled_pages(self):
        pool = KVPagePool(1, 4, kvp=1, hd=8, dtype=jnp.float32)
        p = pool.alloc()
        pool.data[p] = 7.0
        pool.release(p)
        assert np.all(pool.data[pool.alloc()] == 0.0)


class TestPagedSessionRoundTrip:
    def _session(self, lm, p, paged, n=8):
        cfg, params = lm
        backend = TransformerBackend(cfg, params, seq_len=SEQ)
        prompt = jax.random.randint(KEY, (2, SEQ), 0, cfg.vocab_size)
        s = DecodeSession(backend, _manual_plan(p, bits=8.0),
                          max_len=MAX_LEN, qkernels=False, paged=paged,
                          page_tokens=PAGE)
        return s, s.generate(prompt, n), prompt

    @pytest.mark.parametrize("p", [1, 2])
    def test_round_trip_bit_for_bit_every_cut(self, lm, p):
        """``to_dense`` on the live paged structure reproduces the jit
        operand cache exactly on every owned attention slice, and the
        stream's tokens are unchanged by the paging."""
        cfg, params = lm
        s, r, prompt = self._session(lm, p, paged=True)
        s_dense, r_dense, _ = self._session(lm, p, paged=False)
        np.testing.assert_array_equal(r.tokens, r_dense.tokens)
        rebuilt = s.paged_kv.to_dense(
            T.init_cache(cfg, 2, MAX_LEN, s.dev_dtype))
        for layer, (pos, per) in s.paged_kv.attn_layers.items():
            for k in ("k", "v"):
                np.testing.assert_array_equal(
                    np.asarray(rebuilt[pos][k][per]),
                    np.asarray(s.dev_caches[pos][k][per]))

    def test_footprint_monotone_and_under_reservation(self, lm):
        cfg, params = lm
        backend = TransformerBackend(cfg, params, seq_len=SEQ)
        prompt = jax.random.randint(KEY, (1, SEQ), 0, cfg.vocab_size)
        p = cfg.num_layers
        dense = DecodeSession(backend, _manual_plan(p, bits=8.0),
                              max_len=MAX_LEN, paged=False)
        paged = DecodeSession(backend, _manual_plan(p, bits=8.0),
                              max_len=MAX_LEN, paged=True, page_tokens=PAGE)
        tok_d = dense.prefill(prompt)
        tok_p = paged.prefill(prompt)
        sizes = [paged.device_cache_bytes()]
        for _ in range(12):
            tok_d = dense.step(tok_d)
            tok_p = paged.step(tok_p)
            sizes.append(paged.device_cache_bytes())
        assert sizes == sorted(sizes), "resident bytes must be monotone"
        # SEQ=16 + 13 tokens < MAX_LEN=48: strictly under the reservation
        assert sizes[-1] < dense.device_cache_bytes()
        # and exactly the held pages (+ zero dense non-attn remainder
        # for a pure-attention stack)
        assert sizes[-1] == paged.paged_kv.resident_bytes
        held = paged.paged_kv.held_pages
        assert held == paged.page_pool.used_pages

    def test_sever_returns_all_pages(self, lm):
        s, _, _ = self._session(lm, 2, paged=True)
        assert s.page_pool.used_pages > 0
        freed = s.sever()
        assert freed > 0
        assert s.page_pool.used_pages == 0
        assert s.paged_kv.resident_bytes == 0

    def test_shared_pool_two_streams_no_leak(self, lm):
        """Two sessions over ONE pool: pages interleave, both sever
        clean — the fleet-level allocation story at tensor granularity."""
        cfg, params = lm
        backend = TransformerBackend(cfg, params, seq_len=SEQ)
        prompt = jax.random.randint(KEY, (1, SEQ), 0, cfg.vocab_size)
        p = cfg.num_layers
        pool = segment_page_pool(cfg, 0, p, 1, MAX_LEN, jnp.float8_e4m3fn,
                                 page_tokens=PAGE, streams=2)
        ses = [DecodeSession(backend, _manual_plan(p, bits=8.0),
                             max_len=MAX_LEN, paged=True, page_tokens=PAGE,
                             page_pool=pool) for _ in range(2)]
        for s in ses:
            s.generate(prompt, 6)
        assert pool.used_pages == sum(s.paged_kv.held_pages for s in ses)
        for s in ses:
            s.sever()
        assert pool.used_pages == 0


class TestPagedAdmission:
    """kv_bytes_row(tokens=...) + the pricing/serve masks: page-rounded
    actual context admits what the worst-case bound rejects."""

    def _server(self, kv_page_tokens, memory_bytes):
        cfg = dataclasses.replace(get_config("smollm-135m").reduced(),
                                  dtype="float32")
        dev = DeviceProfile(memory_bytes=memory_bytes)
        ch = Channel(capacity_bps=2e6)
        w = ObjectiveWeights()
        srv = QPARTServer()
        stub_transformer_calibration(srv, "lm", cfg, dev, ch, w,
                                     seq_len=SEQ, decode_max_len=512,
                                     kv_page_tokens=kv_page_tokens)
        return srv, (dev, ch, w)

    def test_row_paged_leq_dense_and_monotone(self):
        srv, _ = self._server(16, 2e9)
        be = srv.models["lm"].backend
        dense = be.kv_bytes_row(1)
        short = be.kv_bytes_row(1, tokens=SEQ + 4)
        longer = be.kv_bytes_row(1, tokens=SEQ + 200)
        assert np.all(short <= dense) and np.all(longer <= dense)
        assert np.all(short <= longer)
        assert short[-1] < dense[-1]     # short stream strictly cheaper
        # page rounding: +1 token inside the same page is free
        same = be.kv_bytes_row(1, tokens=SEQ + 5)
        np.testing.assert_array_equal(short, same)

    def _device_memory_between(self):
        """A budget that fits weights + paged KV of a short stream but
        NOT weights + the 512-token worst case, for some cut."""
        srv, _ = self._server(16, 2e9)
        m = srv.models["lm"]
        store = m.store(None)
        lvl = store.level_for(0.05)
        mem = store.level_memory_rows(lvl)
        dense = m.backend.kv_bytes_row(1)
        paged = m.backend.kv_bytes_row(1, tokens=SEQ + 4)
        need_dense = np.asarray(mem) + np.asarray(dense)
        need_paged = np.asarray(mem) + np.asarray(paged)
        # pick a budget between the two for the LAST cut
        c = len(dense) - 1
        assert need_paged[c] < need_dense[c]
        return float((need_paged[c] + need_dense[c]) / 2)

    def test_mask_admits_config_dense_rejects(self):
        """The acceptance criterion: at a device-memory budget BETWEEN
        the paged and worst-case requirements, the ``price_window``
        admission mask rejects the deep cut under dense reservation and
        admits it under page-rounded pricing."""
        budget = self._device_memory_between()
        srv_d, (dev, ch, w) = self._server(None, budget)
        srv_p, _ = self._server(16, budget)
        dev = dataclasses.replace(dev, memory_bytes=budget)
        req = InferenceRequest("lm", 0.05, dev, ch, w, max_new_tokens=4)
        tab_d = price_window(srv_d.models, srv_d.server, [req])
        tab_p = price_window(srv_p.models, srv_p.server, [req])
        c = len(tab_d.obj[0]) - 1                    # the deepest cut
        assert np.isinf(tab_d.obj[0][c]), \
            "worst-case mask should reject the deep cut"
        assert np.isfinite(tab_p.obj[0][c]), \
            "page-rounded mask should admit it"
        # the paged mask only ever widens the feasible set
        feas_d = np.isfinite(tab_d.obj[0])
        feas_p = np.isfinite(tab_p.obj[0])
        assert np.all(feas_p | ~feas_d), "paged must not reject what " \
            "dense admits"

    def test_serve_feasibility_uses_paged_row(self):
        """``QPARTServer.serve`` plans through the same widened mask —
        at the in-between budget the paged server can deploy the deep
        cut, the dense server cannot (its feasible_fn rejects it)."""
        budget = self._device_memory_between()
        srv_d, (dev, ch, w) = self._server(None, budget)
        srv_p, _ = self._server(16, budget)
        dev = dataclasses.replace(dev, memory_bytes=budget)
        req = InferenceRequest("lm", 0.05, dev, ch, w, max_new_tokens=4)
        # both serve successfully (p=0 is always feasible) ...
        p_dense = srv_d.serve(req).plan.p
        p_paged = srv_p.serve(req).plan.p
        L = srv_d.models["lm"].backend.num_layers
        assert p_dense < L
        # ... and the paged feasible set strictly contains the dense one
        kv_d = srv_d.models["lm"].backend.kv_bytes_row(req.batch)
        kv_p = srv_p.models["lm"].backend.kv_bytes_row(
            req.batch, tokens=SEQ + req.max_new_tokens)
        store = srv_d.models["lm"].store(None)
        mem = np.asarray(store.level_memory_rows(store.level_for(0.05)))
        assert mem[L] + kv_d[L] > budget >= mem[L] + kv_p[L]
        assert p_paged >= p_dense


class TestFleetLedger:
    def _stub(self, kv_page_tokens=16):
        cfg = dataclasses.replace(get_config("smollm-135m").reduced(),
                                  dtype="float32")
        # fast channel + expensive server compute: the objective argmin
        # lands on a device cut p > 0, so streams actually hold device KV
        dev = DeviceProfile(memory_bytes=2e9)
        ch = Channel(capacity_bps=2e10)
        w = ObjectiveWeights(eta=1e5)
        srv = QPARTServer()
        stub_transformer_calibration(srv, "lm", cfg, dev, ch, w,
                                     seq_len=SEQ, decode_max_len=64,
                                     kv_page_tokens=kv_page_tokens)
        return srv, (dev, ch, w)

    def test_no_leak_over_seeded_trace(self):
        srv, (dev, ch, w) = self._stub()
        reqs = [InferenceRequest("lm", 0.05, dev, ch, w, arrival_time=0.0,
                                 device_id=f"d{i}", max_new_tokens=20)
                for i in range(5)]
        eng = FleetEngine(srv)
        metrics = eng.run(reqs)
        metrics.assert_terminal()
        led = eng.kv_ledger
        assert led.open_streams == 0
        assert led.resident_bytes == 0
        assert led.total_page_allocs == led.total_page_frees > 0
        assert led.peak_bytes > 0

    def test_no_leak_through_midstream_severance(self):
        srv, (dev, ch, w) = self._stub()
        reqs = [InferenceRequest("lm", 0.05, dev, ch, w, arrival_time=0.0,
                                 device_id="d0", max_new_tokens=40),
                InferenceRequest("lm", 0.05, dev, ch, w, arrival_time=0.0,
                                 device_id="d1", max_new_tokens=40)]
        horizon = FleetEngine(srv).run(reqs).horizon
        faults = [FaultEvent(horizon / 2, DISCONNECT, "d0"),
                  FaultEvent(horizon, RECONNECT, "d0")]
        eng = FleetEngine(srv, faults=faults)
        metrics = eng.run(reqs)
        metrics.assert_terminal()
        assert metrics.records[0].faults == 1       # really severed
        led = eng.kv_ledger
        assert led.open_streams == 0
        assert led.resident_bytes == 0
        assert led.total_page_allocs == led.total_page_frees > 0

    def test_residency_grows_with_stream(self):
        led = PageLedger()
        led.open(0, 100.0, 2)
        led.grow(0, 150.0, 3)
        assert led.resident_bytes == 150.0 and led.resident_pages == 3
        led.grow(0, 140.0, 3)                        # never shrinks
        assert led.resident_bytes == 150.0
        assert led.peak_bytes == 150.0
        assert led.close(0) == 3
        assert led.open_streams == 0 and led.resident_bytes == 0

    def test_legacy_dense_backend_untouched(self):
        """Without kv_page_tokens the ledger stays empty — zero decode-
        lane overhead and bit-identical legacy behavior."""
        srv, (dev, ch, w) = self._stub(kv_page_tokens=None)
        reqs = [InferenceRequest("lm", 0.05, dev, ch, w, arrival_time=0.0,
                                 device_id="d0", max_new_tokens=10)]
        eng = FleetEngine(srv)
        eng.run(reqs).assert_terminal()
        assert eng.kv_ledger.total_page_allocs == 0
        assert eng.kv_ledger.peak_bytes == 0
